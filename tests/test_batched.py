"""Batch-first SpMM tier: ``spmv([B, N])`` parity against looped single
calls on every layer (kernel, simulate runtime, API executors), batched
+ device-loop solver drivers, and solver edge cases (tol early-stop
bookkeeping, cg breakdown)."""
import numpy as np
import pytest

from repro.api import Topology, distribute
from repro.core.nezgt import nezgt_partition
from repro.kernels.spmv import pack_inputs, spmm_shard, spmm_shard_ref
from repro.sparse import csr_from_coo, pack_bell, tile_counts
from repro.sparse.bell import pad_x_blocks
from repro.sparse.formats import COO, coo_from_dense
from repro.sparse.generate import random_coo

B = 8
TOPO = Topology(2, 2)


def _batch_ref(a, xs):
    csr = csr_from_coo(a)
    return np.stack([csr.matvec(xs[i]) for i in range(xs.shape[0])]).astype(
        np.float32
    )


@pytest.fixture(scope="module")
def problem():
    a = random_coo(384, 5000, seed=11)
    xs = (
        np.random.default_rng(5)
        .standard_normal((B, a.shape[1]))
        .astype(np.float32)
    )
    return a, xs, _batch_ref(a, xs)


# -- pad/unpad layout --------------------------------------------------------


def test_pad_x_blocks_batched_layout():
    x = np.arange(10, dtype=np.float32)
    xb = pad_x_blocks(x, 3, 4)
    assert xb.shape == (3, 4)
    xs = np.stack([x, 2 * x])
    xbb = pad_x_blocks(xs, 3, 4)
    assert xbb.shape == (3, 4, 2)  # trailing batch axis
    np.testing.assert_array_equal(xbb[..., 0], xb)
    np.testing.assert_array_equal(xbb[..., 1], 2 * xb)
    with pytest.raises(ValueError, match=r"\[N\] or \[B, N\]"):
        pad_x_blocks(xs[None], 3, 4)


# -- kernel layer ------------------------------------------------------------


def test_kernel_spmm_matches_looped_spmv():
    a = random_coo(192, 1500, seed=0)
    bm = bn = 8
    tc = tile_counts(a, bm, bn)
    owner = nezgt_partition(tc, 3).assignment
    bell = pack_bell(a, owner, 3, bm, bn)
    xs = (
        np.random.default_rng(1)
        .standard_normal((B, a.shape[1]))
        .astype(np.float32)
    )
    for shard in bell.shards:
        tiles, tr, tcg, xb = pack_inputs(shard, xs, bn)
        assert xb.shape[-1] == B
        r = len(shard.row_blocks)
        y_k = np.asarray(spmm_shard(tiles, tr, tcg, xb, r, interpret=True))
        y_o = np.asarray(spmm_shard_ref(tiles, tr, tcg, xb, r))
        assert y_k.shape == (r, bm, B)
        np.testing.assert_allclose(y_k, y_o, rtol=1e-5, atol=1e-5)
        for i in range(B):
            _, _, _, xb1 = pack_inputs(shard, xs[i], bn)
            y_1 = np.asarray(
                spmm_shard(tiles, tr, tcg, xb1[..., None], r, interpret=True)
            )[..., 0]
            np.testing.assert_allclose(y_k[..., i], y_1, rtol=1e-5, atol=1e-5)


# -- API layer: batched == looped through every executor ---------------------


@pytest.mark.parametrize("exchange", ["replicated", "selective", "overlap"])
@pytest.mark.parametrize("executor", ["simulate", "reference"])
def test_spmm_batch_rows_equal_single_calls(problem, exchange, executor):
    a, xs, y_ref = problem
    sess = distribute(a, topology=TOPO, combo="NL-HC", exchange=exchange)
    y_b = sess.spmv(xs, executor=executor)
    assert y_b.shape == (B, a.shape[0])
    for i in range(B):
        y_1 = sess.spmv(xs[i], executor=executor)
        np.testing.assert_allclose(y_b[i], y_1, rtol=1e-5, atol=1e-4)
    err = np.abs(y_b - y_ref).max() / (np.abs(y_ref).max() + 1e-30)
    assert err < 1e-5, (exchange, executor, err)


@pytest.mark.parametrize("exchange", ["selective", "overlap"])
def test_device_spmm_traceable_and_matches(problem, exchange):
    import jax
    import jax.numpy as jnp

    a, xs, y_ref = problem
    sess = distribute(a, topology=TOPO, combo="NL-HL", exchange=exchange)
    mv = sess.device_spmm()
    y = np.asarray(jax.jit(mv)(jnp.asarray(xs)))
    err = np.abs(y - y_ref).max() / np.abs(y_ref).max()
    assert err < 1e-5
    y1 = np.asarray(mv(jnp.asarray(xs[0])))
    assert y1.shape == (a.shape[0],)


def test_costs_batch_amortization(problem):
    a, _, _ = problem
    sess = distribute(a, topology=TOPO, combo="NL-HC", exchange="selective")
    per_rhs = [
        sess.costs(batch=b)["scatter_bytes_per_rhs"] for b in (1, 8, 64)
    ]
    assert per_rhs[0] > per_rhs[1] > per_rhs[2]  # overhead amortizes
    c1, c8 = sess.costs(batch=1), sess.costs(batch=8)
    assert c8["scatter_bytes"] == pytest.approx(8 * c1["scatter_bytes"])
    assert c8["scatter_messages"] == c1["scatter_messages"]
    assert c8["batch"] == 8.0


# -- solvers: batched drivers ------------------------------------------------


def _spd_session(n=96, seed=3):
    rng = np.random.default_rng(seed)
    m = np.where(rng.random((n, n)) < 0.06, rng.standard_normal((n, n)), 0.0)
    spd = m @ m.T + n * np.eye(n)
    a = coo_from_dense(spd.astype(np.float32))
    return distribute(a, topology=TOPO, combo="NL-HC")


def test_block_power_b1_matches_power_iteration():
    sess = _spd_session()
    pi = sess.solve("power_iteration", iters=12)
    bp = sess.solve("block_power_iteration", iters=12, block=1)
    assert bp.value == pytest.approx(pi.value, rel=1e-5)
    np.testing.assert_allclose(np.abs(bp.x[0]), np.abs(pi.x), atol=1e-4)


def test_block_power_rejects_bad_block_sizes():
    sess = _spd_session()
    n = sess.matrix.shape[1]
    with pytest.raises(ValueError, match="block must be in"):
        sess.solve("block_power_iteration", block=0)
    with pytest.raises(ValueError, match="block must be in"):
        sess.solve("block_power_iteration", block=n + 1)


def test_block_power_finds_dominant_eigenvalue():
    sess = _spd_session()
    res = sess.solve("block_power_iteration", iters=80, block=4)
    dense = np.zeros(sess.matrix.shape, np.float64)
    dense[sess.matrix.row, sess.matrix.col] = sess.matrix.val
    top = np.linalg.eigvalsh(dense)[-1]
    assert res.value == pytest.approx(top, rel=1e-3)
    assert res.x.shape == (4, sess.matrix.shape[1])
    # Rows stay orthonormal under QR re-orthonormalization.
    np.testing.assert_allclose(res.x @ res.x.T, np.eye(4), atol=1e-4)


def test_jacobi_batched_matches_looped():
    sess = _spd_session()
    n = sess.matrix.shape[0]
    bs = np.random.default_rng(0).standard_normal((3, n)).astype(np.float32)
    res = sess.solve("jacobi", iters=40, b=bs)
    assert res.x.shape == (3, n)
    for i in range(3):
        r1 = sess.solve("jacobi", iters=40, b=bs[i])
        np.testing.assert_allclose(res.x[i], r1.x, rtol=1e-5, atol=1e-5)


def test_pagerank_multi_source_rows_match_single_seeds():
    a = random_coo(200, 3000, seed=7)
    link = COO(a.shape, a.row, a.col, np.abs(a.val).astype(np.float32))
    sess = distribute(link, topology=TOPO, combo="NL-HL")
    seeds = np.zeros((4, 200), np.float32)
    seeds[np.arange(4), [5, 50, 100, 150]] = 1.0
    res = sess.solve("pagerank", iters=15, seeds=seeds)
    assert res.x.shape == (4, 200)
    np.testing.assert_allclose(np.abs(res.x).sum(axis=1), 1.0, atol=1e-4)
    for i in range(4):
        r1 = sess.solve("pagerank", iters=15, seeds=seeds[i : i + 1])
        np.testing.assert_allclose(res.x[i], r1.x[0], atol=1e-5)
    with pytest.raises(ValueError, match="non-zero mass"):
        sess.solve("pagerank", seeds=np.zeros((2, 200), np.float32))


# -- solvers: device-resident loops ------------------------------------------


@pytest.mark.parametrize(
    "solver,kw",
    [
        ("power_iteration", {}),
        ("block_power_iteration", {"block": 4}),
        ("jacobi", {}),
        ("pagerank", {}),
    ],
)
def test_device_loop_matches_host_loop(solver, kw):
    sess = _spd_session()
    host = sess.solve(solver, iters=10, **kw)
    dev = sess.solve(solver, iters=10, device_loop=True, **kw)
    assert dev.iters_run == host.iters_run == 10
    assert dev.converged == host.converged
    assert len(dev.residuals) == len(host.residuals)
    assert dev.value == pytest.approx(host.value, rel=1e-4, abs=1e-5)
    np.testing.assert_allclose(
        dev.residuals, host.residuals, rtol=1e-3, atol=1e-3
    )


def test_device_loop_on_overlap_exchange():
    """Solver drivers (host and lax.while_loop) run unchanged on the
    pipelined exchange and agree with the blocking one."""
    sess = _spd_session().with_exchange("overlap")
    blocking = _spd_session().solve("jacobi", iters=10)
    host = sess.solve("jacobi", iters=10)
    dev = sess.solve("jacobi", iters=10, device_loop=True)
    np.testing.assert_allclose(host.x, blocking.x, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dev.x, host.x, rtol=1e-4, atol=1e-4)
    assert dev.iters_run == host.iters_run == 10


def test_device_loop_tol_early_stop():
    sess = _spd_session()
    host = sess.solve("jacobi", iters=100, tol=1e-4)
    dev = sess.solve("jacobi", iters=100, tol=1e-4, device_loop=True)
    assert host.converged and dev.converged
    assert dev.iters_run == host.iters_run < 100
    assert dev.residuals[-1] < 1e-4


# -- solver edge cases: tol bookkeeping + cg breakdown -----------------------


@pytest.mark.parametrize(
    "solver,kw",
    [
        ("power_iteration", {}),
        ("jacobi", {}),
        ("pagerank", {}),
        ("cg", {}),
    ],
)
def test_tol_early_stop_bookkeeping(solver, kw):
    sess = _spd_session()
    res = sess.solve(solver, iters=200, tol=1e-3, **kw)
    assert res.converged, (solver, res.residuals[-5:])
    assert res.iters_run < 200
    assert res.residuals[-1] < 1e-3
    # One residual entry per executed iteration (cg logs the initial
    # residual too).
    expected = res.iters_run + (1 if solver == "cg" else 0)
    assert len(res.residuals) == expected, solver


@pytest.mark.parametrize(
    "solver,kw",
    [
        ("power_iteration", {}),
        ("jacobi", {}),
        ("pagerank", {}),
        ("block_power_iteration", {"block": 2}),
    ],
)
def test_no_tol_runs_all_iters_unconverged(solver, kw):
    sess = _spd_session()
    res = sess.solve(solver, iters=5, tol=0.0, **kw)
    assert not res.converged
    assert res.iters_run == 5
    assert len(res.residuals) == 5


def test_cg_breakdown_branch():
    """b = 0 ⇒ r = p = 0 ⇒ pᵀAp = 0: cg must stop on the breakdown
    branch after one iteration, unconverged (tol unset)."""
    sess = _spd_session()
    n = sess.matrix.shape[0]
    res = sess.solve("cg", iters=30, b=np.zeros(n, np.float32))
    assert res.iters_run == 1
    assert not res.converged
    assert res.residuals == [0.0]
    np.testing.assert_array_equal(res.x, np.zeros(n, np.float32))
