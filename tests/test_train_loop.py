"""Training loop: loss decreases, fault recovery, bit-exact resume."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig, get_arch
from repro.data import DataConfig, SyntheticStream
from repro.models import build
from repro.runtime import FaultInjector
from repro.train import TrainLoop, make_train_step


def _setup(tmp_path=None, steps=10, ckpt_every=4, micro=1):
    cfg = get_arch("qwen3-1.7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tc = TrainConfig(
        total_steps=steps, warmup_steps=2, checkpoint_every=ckpt_every,
        learning_rate=1e-2, microbatches=micro,
    )
    step_fn = jax.jit(make_train_step(model, tc))
    dc = DataConfig(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    def batch_fn(s):
        return {"tokens": jnp.asarray(SyntheticStream(dc, start_step=s).batch_at(s))}
    ckpt = CheckpointManager(str(tmp_path), keep=3) if tmp_path else None
    return params, tc, step_fn, batch_fn, ckpt


def test_loss_decreases(tmp_path):
    params, tc, step_fn, batch_fn, _ = _setup(steps=15)
    loop = TrainLoop(step_fn, batch_fn, tc)
    res = loop.run(params, num_steps=15)
    losses = [h["loss"] for h in res.metrics_history]
    assert losses[-1] < losses[0]
    assert res.final_step == 15


def test_fault_recovery_counts(tmp_path):
    params, tc, step_fn, batch_fn, ckpt = _setup(tmp_path, steps=12)
    faults = FaultInjector(schedule={6: 1, 9: 0})
    loop = TrainLoop(step_fn, batch_fn, tc, ckpt=ckpt, fault_injector=faults)
    res = loop.run(params, num_steps=12)
    assert res.restarts == 2
    assert res.final_step == 12
    assert ckpt.latest_step() == 12


def test_resume_is_bit_exact(tmp_path):
    """A run interrupted by a failure must end in exactly the state of an
    uninterrupted run (the data stream is a pure function of step and the
    checkpoint restores params+opt bit-for-bit)."""
    p0, tc, step_fn, batch_fn, _ = _setup(tmp_path / "a", steps=8, ckpt_every=2)
    ckpt_a = CheckpointManager(str(tmp_path / "a"), keep=10)
    loop_a = TrainLoop(step_fn, batch_fn, tc, ckpt=ckpt_a)
    res_a = loop_a.run(p0, num_steps=8)

    ckpt_b = CheckpointManager(str(tmp_path / "b"), keep=10)
    faults = FaultInjector(schedule={5: 0})
    loop_b = TrainLoop(step_fn, batch_fn, tc, ckpt=ckpt_b, fault_injector=faults)
    res_b = loop_b.run(p0, num_steps=8)
    assert res_b.restarts == 1

    for a, b in zip(jax.tree.leaves(res_a.params), jax.tree.leaves(res_b.params)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_microbatch_equivalence():
    """Gradient accumulation (2 microbatches) ~= full-batch step."""
    cfg = get_arch("qwen3-1.7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import init_opt

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab_size)}
    rng = jax.random.PRNGKey(4)
    tc1 = TrainConfig(total_steps=10, warmup_steps=0, microbatches=1, learning_rate=1e-3)
    tc2 = TrainConfig(total_steps=10, warmup_steps=0, microbatches=2, learning_rate=1e-3)
    p1, _, m1 = jax.jit(make_train_step(model, tc1))(params, init_opt(params), batch, rng)
    p2, _, m2 = jax.jit(make_train_step(model, tc2))(params, init_opt(params), batch, rng)
    # Losses match to fp tolerance; param deltas nearly identical.
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=2e-2, atol=2e-4
        )


def test_straggler_monitor_flags():
    from repro.runtime.fault import StragglerMonitor

    mon = StragglerMonitor(factor=3.0)
    for _ in range(5):
        mon.observe(0, 0.1)
    assert mon.observe(6, 1.0) is True
    assert 6 in mon.flagged
    assert mon.observe(7, 0.11) is False
