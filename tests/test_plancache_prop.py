"""Property-based plan-store round-trip: save → cold load → bitwise spmv.

The plan store's contract (DESIGN.md §11) is that serialization is
invisible: for *any* (matrix × topology × combo × exchange × block)
planning run, saving and cold-loading the session — through either the
current sparse v2 format or a legacy v1 archive — must reproduce
``spmv`` bit-for-bit on every in-process executor, single vector and
batched. Hypothesis drives randomized shapes when available (CI installs
it; ``_hypothesis_compat`` skips otherwise); the seeded sweep below
covers the same property offline, plus the lazy/eager load split.
(True cross-*process* cold loads, including shard_map, are pinned by
``test_plancache.py::test_shard_map_warm_start_subprocess``.)
"""
import os
import tempfile

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.api.plancache as plancache
from repro.api import SparseSession, Topology, distribute
from repro.sparse.generate import banded_coo, powerlaw_coo, random_coo

COMBOS = ("NL-HL", "NL-HC", "NC-HL", "NC-HC")
EXCHANGES = ("replicated", "selective", "overlap")


def _round_trip_case(a, topo, combo, exchange, block, version, lazy=True):
    sess = distribute(a, topology=topo, combo=combo, exchange=exchange, block=block)
    rng = np.random.default_rng(7)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    xs = rng.standard_normal((3, a.shape[1])).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plan.npz")
        sess.save(path, format_version=version)
        plancache.clear_memo()  # cold: nothing shared in-process
        loaded = SparseSession.load(path, lazy=lazy)

        # Planning arrays round-trip exactly...
        np.testing.assert_array_equal(
            loaded.partition.elem_unit, sess.partition.elem_unit
        )
        for f in ("tiles", "tile_row", "tile_col", "real_tiles"):
            np.testing.assert_array_equal(
                getattr(loaded.device_plan, f), getattr(sess.device_plan, f),
                err_msg=f"device_plan.{f} (v{version})",
            )
        assert loaded.costs() == sess.costs()
        # ...so execution is bitwise identical on every in-process
        # executor, single and batched.
        for ex in ("simulate", "reference"):
            for xin in (x, xs):
                ya = np.asarray(sess.spmv(xin, executor=ex))
                yb = np.asarray(loaded.spmv(xin, executor=ex))
                assert np.array_equal(ya, yb), (combo, exchange, ex, version)


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(min_value=48, max_value=320),
    density=st.integers(min_value=2, max_value=10),
    nodes=st.integers(min_value=2, max_value=4),
    cores=st.integers(min_value=1, max_value=3),
    combo_i=st.integers(min_value=0, max_value=3),
    exchange_i=st.integers(min_value=0, max_value=2),
    block=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
    version=st.sampled_from([1, 2]),
)
def test_round_trip_property(
    n, density, nodes, cores, combo_i, exchange_i, block, seed, version
):
    a = random_coo(n, n * density, seed=seed)
    _round_trip_case(
        a, Topology(nodes, cores), COMBOS[combo_i], EXCHANGES[exchange_i],
        block, version,
    )


@pytest.mark.parametrize(
    "gen,n,nnz,topo,combo,exchange,block,version,lazy",
    [
        (random_coo, 128, 1200, Topology(2, 2), "NL-HL", "selective", 16, 2, True),
        (random_coo, 128, 1200, Topology(2, 2), "NL-HL", "selective", 16, 1, True),
        (banded_coo, 256, 3000, Topology(2, 3), "NL-HC", "overlap", 16, 2, True),
        (banded_coo, 256, 3000, Topology(2, 3), "NL-HC", "overlap", 16, 1, False),
        (powerlaw_coo, 300, 4500, Topology(3, 2), "NC-HL", "replicated", 8, 2, False),
        (powerlaw_coo, 222, 2200, Topology(2, 2), "nezgt", "selective", 16, 2, True),
        (random_coo, 333, 4000, Topology(2, 4), "NC-HC", "overlap", 8, 1, True),
        (banded_coo, 191, 2000, Topology(4, 1), "hyper", "replicated", 16, 2, True),
    ],
)
def test_round_trip_seeded_sweep(gen, n, nnz, topo, combo, exchange, block, version, lazy):
    """Offline-friendly instantiation of the same property, covering all
    exchanges × both formats × lazy and eager loads."""
    _round_trip_case(gen(n, nnz, seed=n + nnz), topo, combo, exchange, block,
                     version, lazy=lazy)


def test_round_trip_survives_value_view():
    """Saving a with_value_map view bakes the transform into the archive
    (the file stores values, not a recipe): the loaded session matches
    the view bitwise."""
    a = random_coo(150, 1800, seed=5)
    x = np.random.default_rng(1).standard_normal(150).astype(np.float32)
    sess = distribute(a, topology=Topology(2, 2), combo="NL-HC", exchange="overlap")
    view = sess.with_value_map(np.abs)
    with tempfile.TemporaryDirectory() as d:
        path = view.save(os.path.join(d, "plan.npz"))
        loaded = SparseSession.load(path)
        assert loaded.tile_transform is None  # baked, not recorded
        np.testing.assert_array_equal(loaded.matrix.val, np.abs(a.val))
        for ex in ("simulate", "reference"):
            assert np.array_equal(
                np.asarray(view.spmv(x, executor=ex)),
                np.asarray(loaded.spmv(x, executor=ex)),
            )
