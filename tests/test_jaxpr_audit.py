"""Jaxpr collective auditor: golden schedule pins per combo × exchange.

Every pin traces the real stepper through an AbstractMesh — no devices,
no compilation. The overlap pins are the load-bearing ones: they prove
all K all_to_alls are issued before the first contraction, which is the
property the whole §13 pipelining win rests on.
"""
import numpy as np
import pytest

from repro.analysis import (
    audit_jaxpr,
    audit_session,
    golden_signature,
    schedule_signature,
    trace_pmvc_step,
)
from repro.api.session import distribute
from repro.api.topology import Topology
from repro.configs.paper_pmvc import COMBOS
from repro.sparse.generate import PAPER_SUITE, generate

TOPO = Topology(nodes=2, cores=2)


def _session(exchange, combo="NL-HL"):
    a = generate(PAPER_SUITE["bcsstm09"], seed=0)
    return distribute(a, topology=TOPO, combo=combo, exchange=exchange)


# ------------------------------------------------------------- golden pins


@pytest.mark.parametrize("combo", COMBOS)
@pytest.mark.parametrize("waves", [1, 2])
def test_overlap_pins_all_combos(combo, waves):
    rep = audit_session(_session(f"overlap:{waves}", combo))
    assert rep.ok, str(rep)
    assert rep.exchange == "overlap" and rep.waves == waves
    assert rep.signature == golden_signature("overlap", waves)


@pytest.mark.parametrize("exchange", ["replicated", "selective"])
def test_flat_exchange_pins(exchange):
    rep = audit_session(_session(exchange))
    assert rep.ok, str(rep)
    assert rep.signature == golden_signature(exchange)


def test_golden_signature_shape():
    assert golden_signature(None) == "dot psum"
    assert golden_signature("replicated") == "dot psum"
    assert golden_signature("selective") == "a2a dot psum"
    assert golden_signature("overlap", 2) == "a2a a2a dot dot dot psum"
    assert golden_signature("overlap:3", 3).count("a2a") == 3
    with pytest.raises(ValueError):
        golden_signature("carrier-pigeon")


def test_batched_trace_keeps_schedule():
    sess = _session("overlap:2")
    closed = trace_pmvc_step(sess.device_plan, sess.selective, batch=4)
    sig = schedule_signature(closed)
    # Batched lowering may change the contraction primitive mix, but the
    # collectives — the part the audit pins — must be unchanged.
    assert [t for t in sig.split() if t in ("a2a", "psum")] == [
        "a2a",
        "a2a",
        "psum",
    ]


# ------------------------------------------------------- hygiene negatives


def test_wrong_wave_count_is_flagged():
    sess = _session("overlap:2")
    closed = trace_pmvc_step(sess.device_plan, sess.selective)
    findings = audit_jaxpr(closed, expect_waves=3)
    assert any(f.pass_name == "jaxpr/collective-order" for f in findings)
    assert not audit_jaxpr(closed, expect_waves=2)


def test_weak_typed_scan_carry_is_flagged():
    import jax

    def stepper(xs):
        # Python-int carry: weak-typed aval, retraces on first call.
        return jax.lax.scan(lambda c, x: (c + 1, x + c), 0, xs)

    closed = jax.make_jaxpr(stepper)(np.zeros(4, np.float32))
    findings = audit_jaxpr(closed)
    assert any(f.pass_name == "jaxpr/loop-carry" for f in findings)


def test_clean_jaxpr_has_no_findings():
    import jax

    def stepper(xs):
        c0 = np.int32(0)
        return jax.lax.scan(lambda c, x: (c + np.int32(1), x * 2.0), c0, xs)

    closed = jax.make_jaxpr(stepper)(np.zeros(4, np.float32))
    assert audit_jaxpr(closed) == []
