"""Property tests for the selective-exchange plan (DESIGN.md §2.2).

For randomized matrices, topologies, and combos (seeded sweep — no
external property-testing dependency): the static all_to_all schedule
must deliver *exactly* the x blocks each unit's `tile_col` set
requires, each exactly once, and the realized scatter volume must
never exceed the all-gather baseline.
"""
import numpy as np
import pytest

from repro.api import Topology, distribute
from repro.sparse.generate import banded_coo, powerlaw_coo, random_coo

CASES = [
    # (generator, n, nnz, topology, combo, block)
    (random_coo, 128, 1200, Topology(2, 2), "NL-HL", 16),
    (random_coo, 200, 2500, Topology(4, 1), "NC-HC", 16),
    (random_coo, 333, 4000, Topology(3, 2), "NL-HC", 8),
    (banded_coo, 256, 3000, Topology(2, 3), "NC-HL", 16),
    (banded_coo, 512, 5000, Topology(4, 2), "NL-HL", 32),
    (banded_coo, 191, 2000, Topology(2, 2), "nezgt", 16),
    (powerlaw_coo, 300, 4500, Topology(2, 4), "NC-HC", 16),
    (powerlaw_coo, 450, 6000, Topology(3, 3), "NL-HC", 16),
    (powerlaw_coo, 222, 2200, Topology(2, 2), "hyper", 8),
]


def _emulate_all_to_all(sp, xb):
    """Numpy re-execution of the static schedule: returns each unit's
    compact workspace ``ws[u] : [W, bn]``."""
    u_n, lanes, bn = sp.num_units, sp.lanes, xb.shape[1]
    send = np.zeros((u_n, u_n, lanes, bn), np.float32)
    for v in range(u_n):  # sender
        for u in range(u_n):  # destination
            for l in range(lanes):
                loc = sp.send_idx[v, u, l]
                if loc >= 0:
                    send[v, u, l] = xb[sp.owned[v, loc]]
    recv = np.swapaxes(send, 0, 1)  # recv[u, v, l] = send[v, u, l]
    w = sp.recv_src.shape[1]
    ws = np.zeros((u_n, w, bn), np.float32)
    for u in range(u_n):
        ws[u] = recv[u, sp.recv_src[u], sp.recv_lane[u]]
    return ws


@pytest.mark.parametrize("gen,n,nnz,topo,combo,block", CASES)
def test_selective_plan_delivers_exactly_whats_needed(gen, n, nnz, topo, combo, block):
    a = gen(n, nnz, seed=n + nnz)
    sess = distribute(a, topology=topo, combo=combo, exchange="selective", block=block)
    dp, sp = sess.device_plan, sess.selective

    # Distinct per-block content so delivery checks can't pass by luck.
    xb = np.arange(dp.num_col_blocks * dp.bn, dtype=np.float32).reshape(
        dp.num_col_blocks, dp.bn
    )
    ws = _emulate_all_to_all(sp, xb)

    for u in range(topo.units):
        k = int(dp.real_tiles[u])
        required = np.unique(dp.tile_col[u, :k])
        delivered = sp.needed[u][sp.needed[u] >= 0]
        # 1. The delivered set IS the required set — nothing missing,
        #    nothing extra, no duplicates.
        np.testing.assert_array_equal(np.sort(delivered), required)
        assert delivered.shape[0] == np.unique(delivered).shape[0]
        # 2. The workspace slot for each needed block holds that block.
        for i, g in enumerate(sp.needed[u]):
            if g >= 0:
                np.testing.assert_array_equal(ws[u, i], xb[g])
        # 3. tile_col_local points every real tile at the right block.
        for t in range(k):
            np.testing.assert_array_equal(
                ws[u, sp.tile_col_local[u, t]], xb[dp.tile_col[u, t]]
            )

    # 4. Volume: the selective schedule never moves more than all-gather.
    assert sp.wire_blocks <= sp.naive_blocks
    costs = sess.costs()
    assert costs["scatter_bytes"] <= costs["scatter_bytes_naive"] + 1e-9


@pytest.mark.parametrize("seed", range(5))
def test_selective_volume_invariant_random(seed):
    """scatter_bytes <= scatter_bytes_naive over randomized shapes."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(64, 512))
    nnz = int(rng.integers(n, 8 * n))
    topo = Topology(int(rng.integers(2, 5)), int(rng.integers(1, 4)))
    a = random_coo(n, nnz, seed=seed + 100)
    sess = distribute(a, topology=topo, combo="NL-HC", exchange="selective",
                      block=int(rng.choice([8, 16])))
    costs = sess.costs()
    assert costs["scatter_bytes"] <= costs["scatter_bytes_naive"] + 1e-9
    assert 0 < sess.selective.volume_ratio <= 1.0 + 1e-9
