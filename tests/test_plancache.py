"""Plan persistence: save/load round-trip fidelity + cache layers.

The contract (DESIGN.md §10): a loaded session is *bitwise* equivalent —
every planning array round-trips exactly through the ``.npz``, so
``spmv`` through any executor returns bit-identical results, and the
cache key separates any two planning runs that could differ.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.api.plancache as plancache
from repro.api import SparseSession, Topology, distribute
from repro.api.plancache import plan_key
from repro.sparse.generate import random_coo

TOPO = Topology(2, 2)


@pytest.fixture()
def problem():
    a = random_coo(300, 4000, seed=13)
    x = np.random.default_rng(3).standard_normal(a.shape[1]).astype(np.float32)
    xs = np.random.default_rng(4).standard_normal((4, a.shape[1])).astype(np.float32)
    return a, x, xs


@pytest.mark.parametrize("exchange", ["replicated", "selective", "overlap"])
def test_save_load_round_trip_bitwise(problem, exchange, tmp_path):
    a, x, xs = problem
    sess = distribute(a, topology=TOPO, combo="NL-HC", exchange=exchange)
    path = str(tmp_path / "plan.npz")
    assert sess.save(path) == path
    loaded = SparseSession.load(path)
    assert loaded.combo == sess.combo
    assert loaded.exchange == exchange
    assert loaded.topology == sess.topology
    # Planning arrays round-trip exactly.
    np.testing.assert_array_equal(loaded.device_plan.tiles, sess.device_plan.tiles)
    np.testing.assert_array_equal(
        loaded.partition.elem_unit, sess.partition.elem_unit
    )
    # ...so execution is bitwise identical, single and batched, on every
    # in-process executor.
    for ex in ("simulate", "reference"):
        for xin in (x, xs):
            ya = np.asarray(sess.spmv(xin, executor=ex))
            yb = np.asarray(loaded.spmv(xin, executor=ex))
            assert np.array_equal(ya, yb), (exchange, ex)


def test_load_preserves_metrics_and_costs(problem, tmp_path):
    a, _, _ = problem
    sess = distribute(a, topology=TOPO, combo="NC-HL", exchange="selective")
    path = str(tmp_path / "plan.npz")
    sess.save(path)
    loaded = SparseSession.load(path)
    assert loaded.costs() == sess.costs()
    assert loaded.partition.inter_fd == sess.partition.inter_fd
    assert loaded.partition.hyper_cut == sess.partition.hyper_cut
    # Executor can be overridden at load; plans are executor-agnostic.
    ref = SparseSession.load(path, executor="reference")
    assert ref.executor == "reference"


def test_cache_dir_layers(problem, tmp_path):
    a, x, _ = problem
    cache = str(tmp_path / "plans")
    plancache.clear_memo()
    s1 = distribute(a, topology=TOPO, combo="NL-HL", cache_dir=cache)
    files = os.listdir(cache)
    assert len(files) == 1 and files[0].startswith("plan-")
    # Second call: in-process memo — same plan objects, shared compiled
    # closures (with_executor semantics), no second file.
    s2 = distribute(a, topology=TOPO, combo="NL-HL", cache_dir=cache)
    assert s2.device_plan is s1.device_plan
    assert s2._spmv_cache is s1._spmv_cache
    assert os.listdir(cache) == files
    # Simulated fresh process: memo cleared — loads the npz, bitwise.
    plancache.clear_memo()
    s3 = distribute(a, topology=TOPO, combo="NL-HL", cache_dir=cache)
    assert s3.device_plan is not s1.device_plan
    assert np.array_equal(np.asarray(s1.spmv(x)), np.asarray(s3.spmv(x)))
    # Executor override on a memo hit re-wraps without re-planning.
    s4 = distribute(
        a, topology=TOPO, combo="NL-HL", executor="reference", cache_dir=cache
    )
    assert s4.executor == "reference"
    assert s4.device_plan is s3.device_plan


def test_plan_key_separates_planning_inputs(problem):
    a, _, _ = problem
    base = plan_key(a, TOPO, "NL-HL", (16, 16), "selective", 0)
    assert base == plan_key(a, TOPO, "NL-HL", (16, 16), "selective", 0)
    others = [
        plan_key(a, TOPO, "NL-HC", (16, 16), "selective", 0),  # combo
        plan_key(a, TOPO, "NL-HL", (8, 8), "selective", 0),  # block
        plan_key(a, TOPO, "NL-HL", (16, 16), "overlap", 0),  # exchange
        plan_key(a, TOPO, "NL-HL", (16, 16), "selective", 1),  # seed
        plan_key(a, Topology(4, 1), "NL-HL", (16, 16), "selective", 0),  # topo
        plan_key(a, TOPO, "nezgt", (16, 16), "selective", 0, {"dim": "cols"}),
    ]
    assert len({base, *others}) == len(others) + 1
    # Same pattern, different values — content hash must differ.
    b = random_coo(300, 4000, seed=13)
    bumped = type(a)(a.shape, a.row, a.col, a.val + 1.0)
    assert plan_key(bumped, TOPO, "NL-HL", (16, 16), "selective", 0) != base
    assert plan_key(b, TOPO, "NL-HL", (16, 16), "selective", 0) == base  # same seed == same content


def test_memo_hit_still_populates_new_cache_dir(problem, tmp_path):
    """A key planned against cache A must still write the plan file when
    later requested with cache B (and rewrite after eviction) — sibling
    processes pointed at B rely on the file being there."""
    a, x, _ = problem
    dir_a, dir_b = str(tmp_path / "a"), str(tmp_path / "b")
    plancache.clear_memo()
    distribute(a, topology=TOPO, combo="NL-HL", cache_dir=dir_a)
    distribute(a, topology=TOPO, combo="NL-HL", cache_dir=dir_b)  # memo hit
    assert os.listdir(dir_a) == os.listdir(dir_b) != []
    # eviction: the memo hit re-writes the missing file
    victim = os.path.join(dir_a, os.listdir(dir_a)[0])
    os.remove(victim)
    distribute(a, topology=TOPO, combo="NL-HL", cache_dir=dir_a)
    assert os.path.exists(victim)


def test_corrupt_cache_file_treated_as_miss(problem, tmp_path):
    """A torn/corrupt plan file (crashed writer) must be re-planned and
    overwritten, not crash every warm-starting process."""
    a, x, _ = problem
    cache = str(tmp_path / "plans")
    plancache.clear_memo()
    s1 = distribute(a, topology=TOPO, combo="NL-HL", cache_dir=cache)
    path = os.path.join(cache, os.listdir(cache)[0])
    with open(path, "wb") as fh:
        fh.write(b"not a zip archive")
    plancache.clear_memo()
    s2 = distribute(a, topology=TOPO, combo="NL-HL", cache_dir=cache)
    assert np.array_equal(np.asarray(s1.spmv(x)), np.asarray(s2.spmv(x)))
    # ...and the corrupt file was actually *replaced*: a direct load (no
    # re-plan fallback) must succeed and match bitwise.
    s3 = SparseSession.load(path)
    assert np.array_equal(np.asarray(s1.spmv(x)), np.asarray(s3.spmv(x)))


def test_memo_is_lru_bounded(problem, tmp_path, monkeypatch):
    """The in-process memo pins whole sessions (dense tile payloads) —
    it must evict least-recently-used entries past the bound instead of
    growing with every distinct planning key."""
    a, x, _ = problem
    cache = str(tmp_path / "plans")
    plancache.clear_memo()
    monkeypatch.setattr(plancache, "_MEMO_MAX", 2)
    for seed in (0, 1, 2):  # three distinct keys through a bound of two
        distribute(a, topology=TOPO, combo="NL-HL", seed=seed, cache_dir=cache)
    assert len(plancache._MEMO) == 2
    # The evicted key (seed=0) still warm-starts from its npz file.
    s0 = distribute(a, topology=TOPO, combo="NL-HL", seed=0, cache_dir=cache)
    assert np.isfinite(np.asarray(s0.spmv(x))).all()
    plancache.clear_memo()
    assert len(plancache._MEMO) == 0


def test_save_leaves_no_temp_files(problem, tmp_path):
    a, _, _ = problem
    sess = distribute(a, topology=TOPO, combo="NL-HL")
    sess.save(str(tmp_path / "plan.npz"))
    assert sorted(os.listdir(tmp_path)) == ["plan.npz"]


def test_unknown_future_version_rejected(problem, tmp_path, monkeypatch):
    """A file written by a *newer* build (unknown format) must be
    refused outright, not half-parsed."""
    a, _, _ = problem
    sess = distribute(a, topology=TOPO, combo="NL-HL")
    path = str(tmp_path / "plan.npz")
    future = plancache.FORMAT_VERSION + 1
    monkeypatch.setattr(plancache, "FORMAT_VERSION", future)
    monkeypatch.setattr(plancache, "READABLE_VERSIONS", (1, 2, future))
    sess.save(path)  # stamps the future version into meta
    monkeypatch.undo()
    with pytest.raises(ValueError, match=f"format v{future}"):
        SparseSession.load(path)


def test_v1_archive_reads_transparently(problem, tmp_path):
    """Legacy (padded, PR 4-era) archives keep loading bitwise under the
    v2-writing build — the fleet migration path."""
    a, x, xs = problem
    sess = distribute(a, topology=TOPO, combo="NL-HC", exchange="overlap")
    v1 = str(tmp_path / "v1.npz")
    v2 = str(tmp_path / "v2.npz")
    sess.save(v1, format_version=1)
    sess.save(v2)
    # The sparse format drops the padding bloat on disk.
    assert os.path.getsize(v2) < os.path.getsize(v1)
    a1 = SparseSession.load(v1)
    a2 = SparseSession.load(v2)
    for loaded in (a1, a2):
        np.testing.assert_array_equal(
            loaded.device_plan.tiles, sess.device_plan.tiles
        )
        np.testing.assert_array_equal(
            loaded.selective.selective.tile_col_local,
            sess.selective.selective.tile_col_local,
        )
        for ex in ("simulate", "reference"):
            for xin in (x, xs):
                assert np.array_equal(
                    np.asarray(sess.spmv(xin, executor=ex)),
                    np.asarray(loaded.spmv(xin, executor=ex)),
                )


def test_lazy_load_defers_payload(problem, tmp_path):
    """SparseSession.load is lazy by default: nothing but the meta entry
    is touched until an executor needs the plan, and materialization is
    shared across with_executor re-wraps."""
    a, x, _ = problem
    sess = distribute(a, topology=TOPO, combo="NL-HL")
    path = str(tmp_path / "plan.npz")
    sess.save(path)
    loaded = SparseSession.load(path)
    assert not loaded.is_materialized
    assert "unmaterialized" in repr(loaded)
    sibling = loaded.with_executor("reference")
    assert not loaded.is_materialized  # re-wrap must not force the thunks
    y = np.asarray(sibling.spmv(x))  # CSR oracle: reads the matrix only...
    assert callable(sibling._device_plan)  # ...tiles stay on disk
    assert np.array_equal(y, np.asarray(sess.spmv(x, executor="reference")))
    y2 = np.asarray(loaded.spmv(x))  # simulate: now the tiles materialize
    assert not callable(loaded._device_plan)
    assert loaded.device_plan is sibling.device_plan  # once, shared
    assert np.array_equal(y2, np.asarray(sess.spmv(x)))
    eager = SparseSession.load(path, lazy=False)
    assert eager.is_materialized


_SUBPROC = textwrap.dedent(
    """
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import repro.api.plancache as plancache
    from repro.api import SparseSession, Topology, distribute
    from repro.sparse.generate import random_coo

    cache = sys.argv[1]
    a = random_coo(256, 3000, seed=9)
    x = np.random.default_rng(1).standard_normal(a.shape[1]).astype(np.float32)
    sess = distribute(a, topology=Topology(2, 2), combo="NL-HC",
                      exchange="overlap", executor="shard_map",
                      cache_dir=cache)
    y_cold = np.asarray(sess.spmv(x))
    plancache.clear_memo()  # simulate a sibling process warm-starting
    warm = distribute(a, topology=Topology(2, 2), combo="NL-HC",
                      exchange="overlap", executor="shard_map",
                      cache_dir=cache)
    assert warm.device_plan is not sess.device_plan
    y_warm = np.asarray(warm.spmv(x))
    assert np.array_equal(y_cold, y_warm), "shard_map warm-start not bitwise"
    print("PLANCACHE_SHARDED_OK")
    """
)


def test_shard_map_warm_start_subprocess(tmp_path):
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROC, str(tmp_path / "plans")],
        capture_output=True,
        text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "PLANCACHE_SHARDED_OK" in res.stdout, res.stdout + res.stderr
