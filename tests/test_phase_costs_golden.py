"""Golden-value regression tests for :func:`repro.pmvc.dist.phase_costs`.

A hand-built 8×8 matrix with an explicit element→unit assignment pins
*every* scatter/gather/local/halo byte and time term to exact,
hand-derived values, so future cost-model edits cannot silently drift
(the fields were previously asserted only relationally).

Layout under ``bm = bn = 2`` (4 block-rows × 4 block-cols, 2 units):

* unit 0 owns block-rows {0, 1} and tiles (0,0) (0,2) (1,1) (1,3);
* unit 1 owns block-rows {2, 3} and tiles (2,2) (2,0) (3,3) (3,1);
* x ownership: unit 0 holds block-cols {0, 1}, unit 1 holds {2, 3}.

So each unit has 4 real tiles — 2 local, 2 halo — and the selective
schedule moves 4 blocks across the wire (2 per direction ⇒ 2 messages).
"""
import numpy as np
import pytest

from repro.pmvc.dist import (
    MESSAGE_OVERHEAD_BYTES,
    MODEL_LINK_BYTES_PER_S,
    MODEL_UNIT_FLOPS_PER_S,
    phase_costs,
)
from repro.pmvc.plan_device import (
    build_overlap_plan,
    build_selective_plan,
    pack_units,
)
from repro.sparse.formats import COO


def _fixed_plan():
    row = np.array([0, 1, 2, 3, 4, 5, 6, 7, 0, 2, 4, 6])
    col = np.array([0, 1, 2, 3, 4, 5, 6, 7, 4, 6, 0, 2])
    val = np.arange(1, 13, dtype=np.float32)
    a = COO((8, 8), row, col, val)
    elem_unit = (row >= 4).astype(np.int64)  # rows 0–3 → unit 0, 4–7 → unit 1
    return pack_units(a, elem_unit, 2, 2, 2)


@pytest.fixture(scope="module")
def plans():
    dp = _fixed_plan()
    sp = build_selective_plan(dp)
    op = build_overlap_plan(dp, sp)
    return dp, sp, op


def test_fixed_plan_structure(plans):
    dp, sp, op = plans
    assert dp.t == 4
    np.testing.assert_array_equal(dp.real_tiles, [4, 4])
    assert sp.wire_blocks == 4
    np.testing.assert_array_equal(op.local_counts, [2, 2])
    np.testing.assert_array_equal(op.halo_counts, [2, 2])
    assert (op.t_local, op.t_halo) == (2, 2)
    assert op.local_fraction == 0.5


def test_model_constants_pinned():
    """The time terms below bake these in — changing a constant is a
    deliberate cost-model change and must update the goldens."""
    assert MESSAGE_OVERHEAD_BYTES == 512
    assert MODEL_LINK_BYTES_PER_S == 1.25e9
    assert MODEL_UNIT_FLOPS_PER_S == 5.0e10


def test_phase_costs_selective_golden(plans):
    dp, sp, _ = plans
    c = phase_costs(dp, sp)
    expected = {
        "batch": 1.0,
        # 4 wire blocks × bn=2 × 4 bytes.
        "scatter_bytes": 32.0,
        # (U−1)=1 × NCB=4 × bn=2 × 4 bytes.
        "scatter_bytes_naive": 32.0,
        "scatter_messages": 2.0,
        "scatter_overhead_bytes": 1024.0,
        "scatter_bytes_per_rhs": 1056.0,
        # 2 × U=2 × T=4 × bm×bn=4.
        "compute_flops": 64.0,
        "useful_flops": 64.0,
        "flop_efficiency": 1.0,
        # U=2 × NRB=4 × bm=2 × 4 bytes.
        "gather_bytes": 64.0,
        "gather_bytes_per_rhs": 64.0 + 2 * 512.0,
        # U=2 × T=4 × 2×2×4 bytes.
        "tile_bytes_resident": 128.0,
        "t_scatter": 1056.0 / 1.25e9,
        "t_gather": 1088.0 / 1.25e9,
        "t_compute": 32.0 / 5.0e10,
        "t_iter_blocking": 1056.0 / 1.25e9 + 1088.0 / 1.25e9 + 32.0 / 5.0e10,
    }
    assert set(c) == set(expected)
    for key, want in expected.items():
        assert c[key] == pytest.approx(want, rel=1e-12, abs=0.0), key


def test_phase_costs_overlap_golden(plans):
    dp, _, op = plans
    c = phase_costs(dp, op)
    t_scatter = 1056.0 / 1.25e9
    t_local = 16.0 / 5.0e10  # 2 × TL=2 × bm×bn=4 per unit
    t_halo = 16.0 / 5.0e10
    t_gather = 1088.0 / 1.25e9
    t_blocking = t_scatter + 32.0 / 5.0e10 + t_gather
    t_overlap = max(t_scatter, t_local) + t_halo + t_gather
    expected = {
        # The wire payload is exactly the halo fan-out…
        "halo_bytes": 32.0,
        # …and 4 owned-and-referenced blocks are read in place.
        "local_x_bytes": 32.0,
        "local_tile_fraction": 0.5,
        "t_local": t_local,
        "t_halo": t_halo,
        "t_iter_overlap": t_overlap,
        "overlap_efficiency": t_local / t_scatter,  # comm-bound case
        "overlap_speedup": t_blocking / t_overlap,
    }
    for key, want in expected.items():
        assert c[key] == pytest.approx(want, rel=1e-12, abs=0.0), key
    # The volume terms agree with the embedded selective plan's.
    sel = phase_costs(dp, op.selective)
    for key, want in sel.items():
        assert c[key] == pytest.approx(want, rel=1e-12, abs=0.0), key


def test_phase_costs_overlap_batch_scaling(plans):
    """Payload terms scale with B; per-message overhead does not."""
    dp, _, op = plans
    c = phase_costs(dp, op, batch=4)
    assert c["batch"] == 4.0
    assert c["scatter_bytes"] == 128.0
    assert c["scatter_overhead_bytes"] == 1024.0
    assert c["scatter_bytes_per_rhs"] == (128.0 + 1024.0) / 4
    assert c["halo_bytes"] == 128.0
    assert c["local_x_bytes"] == 128.0
    assert c["t_local"] == pytest.approx(64.0 / 5.0e10, rel=1e-12)
    assert c["t_scatter"] == pytest.approx(1152.0 / 1.25e9, rel=1e-12)
    # Still comm-bound: efficiency grows with B as t_local catches up.
    c1 = phase_costs(dp, op, batch=1)
    assert c["overlap_efficiency"] > c1["overlap_efficiency"]


def test_phase_costs_replicated_has_no_overlap_terms(plans):
    dp, _, _ = plans
    c = phase_costs(dp, None)
    for key in ("t_local", "t_halo", "overlap_efficiency", "halo_bytes"):
        assert key not in c
    assert c["scatter_bytes"] == c["scatter_bytes_naive"] == 32.0
    # all-gather: U×(U−1) messages.
    assert c["scatter_messages"] == 2.0
