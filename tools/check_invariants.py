#!/usr/bin/env python
"""AST-level repo invariant rules — the lints ruff can't express.

Three rules, run in CI (the ``static-analysis`` job) and locally via
``python tools/check_invariants.py``:

R1  no cross-subpackage private imports: ``from repro.<pkg>...`` may
    only import underscore-prefixed names/modules from inside the same
    ``repro.<pkg>`` subpackage. A private helper is a subpackage's
    internal contract; reaching across freezes it accidentally.
    Exceptions live in ``PRIVATE_IMPORT_WHITELIST``.

R2  no unseeded randomness in tests/ and benchmarks/: every random
    draw must flow from an explicit seed — ``np.random.default_rng(0)``
    yes; the legacy global-state ``np.random.rand(...)`` / bare
    ``default_rng()`` / stdlib ``random`` module no. Parity suites and
    benchmark inputs must replay bit-identically.

R3  registry-decorator conventions: every ``@register_*(...)``
    decorator takes a string-literal first argument (grep-able — a
    computed name defeats "where is this solver defined"), and no
    (registry, name) pair is registered twice.

Exit status: 0 clean, 1 findings, 2 usage error. Output is
``path:line: RULE message`` — one line per finding.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import Iterator, List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (importing module path relative to repo, imported dotted name) pairs
# allowed to cross a subpackage boundary with a private name.
PRIVATE_IMPORT_WHITELIST: frozenset = frozenset()

# Legacy numpy global-state entry points (np.random.X). Seeded
# constructors are fine; everything else draws from hidden global state.
_SEEDED_RANDOM_ATTRS = {"default_rng", "Generator", "SeedSequence", "RandomState"}

_SCAN_ROOTS = ("src", "tests", "benchmarks", "tools")
_RANDOMNESS_ROOTS = ("tests", "benchmarks")


def _py_files(*roots: str) -> Iterator[str]:
    for root in roots:
        base = os.path.join(REPO, root)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def _rel(path: str) -> str:
    return os.path.relpath(path, REPO)


def _subpackage_of_module(rel_path: str) -> str | None:
    """``src/repro/api/session.py`` -> ``api``; None outside repro."""
    parts = rel_path.split(os.sep)
    if len(parts) >= 3 and parts[0] == "src" and parts[1] == "repro":
        return parts[2].removesuffix(".py")
    return None


def _subpackage_of_import(dotted: str) -> str | None:
    parts = dotted.split(".")
    if len(parts) >= 2 and parts[0] == "repro":
        return parts[1]
    return None


def _is_private(name: str) -> bool:
    """Single-underscore names are private; dunders (``__main__``,
    ``__version__``, ...) are python-protocol names, not hidden API."""
    return name.startswith("_") and not (
        name.startswith("__") and name.endswith("__")
    )


def check_private_imports(path: str, tree: ast.AST) -> List[Tuple[int, str]]:
    rel = _rel(path)
    own = _subpackage_of_module(rel)
    out: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.level or not node.module:
                continue  # relative imports stay inside their package
            src_pkg = _subpackage_of_import(node.module)
            if src_pkg is None:
                continue
            private_names = [
                a.name for a in node.names if _is_private(a.name)
            ]
            private_module = any(_is_private(p) for p in node.module.split("."))
            if not private_names and not private_module:
                continue
            if own == src_pkg:
                continue
            what = private_names[0] if private_names else node.module
            if (rel, f"{node.module}.{what}") in PRIVATE_IMPORT_WHITELIST or (
                rel,
                node.module,
            ) in PRIVATE_IMPORT_WHITELIST:
                continue
            out.append(
                (
                    node.lineno,
                    f"R1 private import across subpackages: {rel} "
                    f"(repro.{own or '<outside>'}) imports {what!r} from "
                    f"{node.module} — add to PRIVATE_IMPORT_WHITELIST or "
                    "export a public name",
                )
            )
        elif isinstance(node, ast.Import):
            for a in node.names:
                src_pkg = _subpackage_of_import(a.name)
                if src_pkg is None or own == src_pkg:
                    continue
                if any(_is_private(p) for p in a.name.split(".")):
                    out.append(
                        (
                            node.lineno,
                            f"R1 private module import across subpackages: "
                            f"{a.name}",
                        )
                    )
    return out


def _is_np_random_attr(node: ast.AST) -> str | None:
    """``np.random.X`` / ``numpy.random.X`` -> ``"X"``."""
    if not isinstance(node, ast.Attribute):
        return None
    mid = node.value
    if (
        isinstance(mid, ast.Attribute)
        and mid.attr == "random"
        and isinstance(mid.value, ast.Name)
        and mid.value.id in ("np", "numpy")
    ):
        return node.attr
    return None


def check_randomness(path: str, tree: ast.AST) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            attr = _is_np_random_attr(node.func)
            if attr is not None:
                if attr not in _SEEDED_RANDOM_ATTRS:
                    out.append(
                        (
                            node.lineno,
                            f"R2 unseeded global-state randomness: "
                            f"np.random.{attr}(...) — use "
                            "np.random.default_rng(seed)",
                        )
                    )
                elif not node.args and not node.keywords:
                    out.append(
                        (
                            node.lineno,
                            f"R2 np.random.{attr}() without a seed — "
                            "entropy-seeded, not replayable",
                        )
                    )
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            names = (
                [a.name for a in node.names]
                if isinstance(node, ast.Import)
                else [node.module or ""]
            )
            if any(n == "random" or n.startswith("random.") for n in names):
                out.append(
                    (
                        node.lineno,
                        "R2 stdlib random module in tests/benchmarks — "
                        "use np.random.default_rng(seed)",
                    )
                )
    return out


def check_registry_decorators(
    path: str, tree: ast.AST, seen: dict
) -> List[Tuple[int, str]]:
    out: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        for deco in node.decorator_list:
            if not isinstance(deco, ast.Call):
                continue
            fn = deco.func
            name = fn.id if isinstance(fn, ast.Name) else getattr(fn, "attr", "")
            if not name.startswith("register_"):
                continue
            if not deco.args or not (
                isinstance(deco.args[0], ast.Constant)
                and isinstance(deco.args[0].value, str)
            ):
                out.append(
                    (
                        deco.lineno,
                        f"R3 @{name}(...) first argument must be a string "
                        "literal (grep-able registry key)",
                    )
                )
                continue
            key = (name, deco.args[0].value)
            if key in seen:
                prev = seen[key]
                out.append(
                    (
                        deco.lineno,
                        f"R3 duplicate registration @{name}"
                        f"({deco.args[0].value!r}) — first registered at "
                        f"{prev}",
                    )
                )
            else:
                seen[key] = f"{_rel(path)}:{deco.lineno}"
    return out


def main(argv=None) -> int:
    findings: List[str] = []
    registry_seen: dict = {}
    for path in _py_files(*_SCAN_ROOTS):
        try:
            with open(path, encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
        except SyntaxError as e:
            findings.append(f"{_rel(path)}:{e.lineno or 0}: unparseable: {e.msg}")
            continue
        hits = check_private_imports(path, tree)
        hits += check_registry_decorators(path, tree, registry_seen)
        rel = _rel(path)
        if rel.split(os.sep)[0] in _RANDOMNESS_ROOTS:
            hits += check_randomness(path, tree)
        findings.extend(f"{rel}:{line}: {msg}" for line, msg in sorted(hits))
    for f in findings:
        print(f)
    if findings:
        print(f"\n{len(findings)} invariant violation(s)", file=sys.stderr)
        return 1
    print("invariants OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
